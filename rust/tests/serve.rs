//! End-to-end tests of the persistent private-inference server: the
//! acceptance pins of the serving layer (DESIGN.md §Serving layer).
//!
//! * **Byte-identity** — answers served through the front-end + scheduler
//!   equal a direct `private_eval_batch` over the same queries in arrival
//!   order, on both backends (Sim and TCP members).
//! * **Partition invariance** — however the scheduler slices arrivals
//!   into ticks (a race by design), the revealed roots are unchanged:
//!   the tag-stripe invariant of `spn::plan`.
//! * **Tag freshness** — N scheduler ticks of mixed widths reserve
//!   strictly monotone, pairwise disjoint tag ranges (the PR 3 "tags are
//!   never reused" contract under the scheduler).
//! * **Concurrency + clean shutdown** — ≥8 concurrent clients over real
//!   TCP members; every thread joined, member threads joined, report
//!   totals exact.
//!
//! Everything runs on `Structure::mini_demo()` — no artifacts needed, so
//! these tests run in CI on a fresh checkout.

use std::net::TcpListener;
use std::thread;
use std::time::Duration;

use spn_mpc::coordinator::infer::private_eval_batch;
use spn_mpc::coordinator::serve::train_and_serve;
use spn_mpc::coordinator::train::{train, TrainConfig};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::net::serve::{ServeClient, ServeConfig, ServeReport};
use spn_mpc::net::tcp_session::{TcpSession, TcpSessionConfig};
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::spn::plan::{EvalPlan, Evaluator, Query};
use spn_mpc::spn::structure::Structure;
use spn_mpc::spn::learn;

const MEMBERS: usize = 3;

fn mini_counts(st: &Structure, n: usize) -> (Vec<Vec<u64>>, u64) {
    // seeds 5/21: the same shards as integration.rs's cross-backend tests
    (datasets::synth_shard_counts(st, n, st.rows, 5, 21), st.rows as u64)
}

// Under `--features checked-session` the *served* sessions run wrapped in
// the CheckedSession sanitizer while the oracles stay raw — byte-identity
// of checked serving against an unchecked oracle is the stronger pin.
// By default wrap() is the identity.
#[cfg(feature = "checked-session")]
use spn_mpc::protocols::checked::CheckedSession;
#[cfg(feature = "checked-session")]
fn wrap<S: spn_mpc::protocols::MpcSession>(s: S) -> CheckedSession<S> {
    CheckedSession::new(s)
}
#[cfg(not(feature = "checked-session"))]
fn wrap<S: spn_mpc::protocols::MpcSession>(s: S) -> S {
    s
}
#[cfg(feature = "checked-session")]
fn wrap_engine(e: Engine) -> CheckedSession<Engine> {
    let schedule = e.cfg.schedule;
    CheckedSession::with_sim_accounting(e, schedule)
}
#[cfg(not(feature = "checked-session"))]
fn wrap_engine(e: Engine) -> Engine {
    e
}
#[cfg(feature = "checked-session")]
fn unwrap_session<S: spn_mpc::protocols::MpcSession>(s: CheckedSession<S>) -> S {
    s.into_inner()
}
#[cfg(not(feature = "checked-session"))]
fn unwrap_session<S: spn_mpc::protocols::MpcSession>(s: S) -> S {
    s
}

/// A deterministic mixed stream: mostly single-evidence marginals, every
/// fifth query fully marginalized.
fn arrival_queries(st: &Structure, total: usize) -> Vec<Query> {
    (0..total)
        .map(|i| {
            let mut q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
            if i % 5 != 0 {
                let v = i % st.num_vars;
                q.x[v] = ((i / 2) % 2) as u8;
                q.marg[v] = false;
            }
            q
        })
        .collect()
}

/// The oracle: a fresh identically-seeded Sim session, identical training,
/// one direct `private_eval_batch` over the queries in arrival order.
fn sim_oracle(st: &Structure, n: usize, queries: &[Query]) -> Vec<i128> {
    let (counts, rows) = mini_counts(st, n);
    let theta = learn::default_leaf_theta(st);
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(n).batched());
    let (model, _) = train(&mut eng, st, &counts, rows, &TrainConfig::default());
    let (roots, _) = private_eval_batch(&mut eng, st, &model, queries, &theta);
    roots
}

/// Bind an ephemeral listener, then train + serve on a background thread
/// over the requested backend. Returns the address and the join handle
/// yielding the final [`ServeReport`].
fn spawn_server(
    backend: &'static str,
    st: Structure,
    n: usize,
    cfg: ServeConfig,
) -> (std::net::SocketAddr, thread::JoinHandle<ServeReport>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let h = thread::spawn(move || {
        let (counts, rows) = mini_counts(&st, n);
        let theta = learn::default_leaf_theta(&st);
        let tcfg = TrainConfig::default();
        match backend {
            "tcp" => {
                let mut sess = wrap(
                    TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(n)).unwrap(),
                );
                let (report, _) =
                    train_and_serve(&mut sess, &st, &counts, rows, &tcfg, &theta, listener, &cfg)
                        .unwrap();
                // member threads join here: a leak would hang the test
                unwrap_session(sess).shutdown().unwrap();
                report
            }
            _ => {
                let mut eng = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(n).batched()));
                let (report, _) =
                    train_and_serve(&mut eng, &st, &counts, rows, &tcfg, &theta, listener, &cfg)
                        .unwrap();
                report
            }
        }
    });
    (addr, h)
}

#[test]
fn served_answers_match_direct_batch_arrival_order() {
    let st = Structure::mini_demo();
    let queries = arrival_queries(&st, 9);
    let want = sim_oracle(&st, MEMBERS, &queries);
    for backend in ["sim", "tcp"] {
        let cfg = ServeConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            max_queries: None,
        };
        let (addr, h) = spawn_server(backend, st.clone(), MEMBERS, cfg);
        let mut c = ServeClient::connect(&addr.to_string()).unwrap();
        assert_eq!(c.hello.num_vars, st.num_vars);
        assert_eq!(c.hello.d, 256);
        let mut got = Vec::new();
        let mut prev_total = 0u64;
        for q in &queries {
            let r = c.query(q).unwrap();
            assert!(r.stats.rounds > 0, "each response carries its tick's delta");
            assert!(r.total.messages >= prev_total, "per-client totals accumulate");
            prev_total = r.total.messages;
            got.push(r.root);
        }
        // a second connection issues the shutdown command
        ServeClient::connect(&addr.to_string()).unwrap().shutdown_server().unwrap();
        let report = h.join().unwrap();
        assert_eq!(
            got, want,
            "{backend}: served roots must equal a direct private_eval_batch in arrival order"
        );
        assert_eq!(report.queries, queries.len() as u64);
        assert!(report.batches >= 1 && report.batches <= queries.len() as u64);
    }
}

#[test]
fn served_answers_are_tick_partition_invariant() {
    // One client pipelines every query before reading any response, so the
    // scheduler slices the arrival sequence into ticks of up to max_batch
    // at whatever rhythm the race dictates — the roots must still equal
    // the single direct batch (overall query j always gets tag block j·m).
    let st = Structure::mini_demo();
    let total = 13usize;
    let queries = arrival_queries(&st, total);
    let want = sim_oracle(&st, MEMBERS, &queries);
    let cfg = ServeConfig {
        max_batch: 5,
        max_wait: Duration::from_millis(1),
        max_queries: Some(total as u64),
    };
    let (addr, h) = spawn_server("sim", st.clone(), MEMBERS, cfg);
    let mut c = ServeClient::connect(&addr.to_string()).unwrap();
    for q in &queries {
        c.send(q).unwrap();
    }
    let mut got = Vec::new();
    let mut seqs = Vec::new();
    for _ in 0..total {
        let r = c.recv().unwrap();
        assert!(r.batch >= 1 && r.batch <= 5);
        got.push(r.root);
        seqs.push(r.seq);
    }
    let report = h.join().unwrap(); // max_queries reached → self-shutdown
    assert_eq!(got, want, "tick partition must not change any revealed root");
    assert_eq!(
        seqs,
        (0..total as u64).collect::<Vec<_>>(),
        "per-connection responses arrive in request order"
    );
    assert!(report.max_tick <= 5);
    assert_eq!(report.queries, total as u64);
}

#[test]
fn concurrent_clients_match_oracle_and_shut_down_cleanly() {
    // The CI smoke, in-process: 8 clients × 3 identical queries over real
    // TCP members. Arrival order is racy, but identical queries make the
    // position multiset fixed — sorted served roots must equal the sorted
    // roots of one direct 24-query Sim batch (TCP ≡ Sim under one seed).
    let st = Structure::mini_demo();
    let clients = 8usize;
    let per = 3usize;
    let total = clients * per;
    let q = Query { x: vec![1, 0], marg: vec![false, true] };
    let queries: Vec<Query> = (0..total).map(|_| q.clone()).collect();
    let mut want = sim_oracle(&st, MEMBERS, &queries);
    want.sort_unstable();
    let cfg = ServeConfig {
        max_batch: 6,
        // generous wait so ticks coalesce reliably even on a loaded runner
        max_wait: Duration::from_millis(20),
        max_queries: Some(total as u64),
    };
    let (addr, h) = spawn_server("tcp", st.clone(), MEMBERS, cfg);
    let mut handles = Vec::new();
    for _ in 0..clients {
        let a = addr.to_string();
        let q = q.clone();
        handles.push(thread::spawn(move || {
            let mut c = ServeClient::connect(&a).unwrap();
            (0..per).map(|_| c.query(&q).unwrap().root).collect::<Vec<i128>>()
        }));
    }
    let mut got: Vec<i128> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    got.sort_unstable();
    let report = h.join().unwrap(); // joins = clean shutdown, nothing leaked
    assert_eq!(got, want, "concurrent served roots must be the oracle multiset");
    assert_eq!(report.queries, total as u64);
    assert_eq!(report.clients, clients as u64);
    assert!(report.max_tick >= 2, "concurrent load must actually coalesce ticks");
}

#[test]
fn scheduler_ticks_reserve_disjoint_monotone_tag_ranges() {
    // The PR 3 contract under the scheduler: every eval_batch tick
    // reserves a fresh tag block; N ticks of mixed widths must produce
    // strictly monotone, pairwise disjoint [start, end) ranges of width
    // m·B — tags are never reused across ticks.
    let st = Structure::mini_demo();
    let (counts, rows) = mini_counts(&st, MEMBERS);
    let theta = learn::default_leaf_theta(&st);
    let mut eng = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(MEMBERS).batched()));
    let (model, _) = train(&mut eng, &st, &counts, rows, &TrainConfig::default());
    let plan = EvalPlan::compile(&st, &theta, model.d);
    let m = plan.divpubs_per_query;
    assert!(m > 0);
    let mut ev = Evaluator::new(plan);
    assert!(ev.last_tags().is_none());

    let widths = [1usize, 3, 2, 7, 1, 5, 4, 2, 6, 1]; // mixed traffic
    let mut ranges: Vec<(u64, u64)> = Vec::new();
    for (t, &w) in widths.iter().enumerate() {
        let batch = arrival_queries(&st, w);
        let (roots, _) = ev.eval_batch(&mut eng, &batch, &model.sum_w, model.leaf_theta.as_deref());
        assert_eq!(roots.len(), w);
        let (start, end) = ev.last_tags().unwrap();
        assert_eq!(end - start, m * w as u64, "tick {t}: block width must be m·B");
        if let Some(&(_, prev_end)) = ranges.last() {
            assert!(
                start >= prev_end,
                "tick {t}: ranges must be monotone (start {start} < prev end {prev_end})"
            );
        }
        ranges.push((start, end));
        assert_eq!(ev.ticks(), (t + 1) as u64);
    }
    for i in 0..ranges.len() {
        for j in i + 1..ranges.len() {
            let (a, b) = ranges[i];
            let (c, d) = ranges[j];
            assert!(b <= c || d <= a, "tag ranges of ticks {i} and {j} overlap");
        }
    }
}

#[test]
fn malformed_queries_get_error_replies_without_killing_the_connection() {
    let st = Structure::mini_demo();
    let cfg = ServeConfig {
        max_batch: 2,
        max_wait: Duration::from_millis(1),
        max_queries: None,
    };
    let (addr, h) = spawn_server("sim", st.clone(), MEMBERS, cfg);
    let mut c = ServeClient::connect(&addr.to_string()).unwrap();
    for bad in ["{\"x\":[1],\"marg\":[true]}", "{\"cmd\":\"nope\"}", "not json"] {
        c.send_raw(bad).unwrap();
        let err = c.recv().unwrap_err().to_string();
        assert!(err.contains("server error"), "{bad} must produce an error reply, got {err}");
    }
    // the connection survives and still answers real queries
    let r = c.query(&Query { x: vec![0, 0], marg: vec![true, true] }).unwrap();
    assert!((r.root - 256).abs() <= 32, "S(∅)·d = {}", r.root);
    ServeClient::connect(&addr.to_string()).unwrap().shutdown_server().unwrap();
    let report = h.join().unwrap();
    assert_eq!(report.queries, 1, "malformed frames must not reach the scheduler");
}
