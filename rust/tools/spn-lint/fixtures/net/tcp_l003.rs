//! L003 fixture: a hash map creeping into a `net/tcp` data-plane path.

use std::collections::HashMap;

fn store() -> HashMap<u64, u128> { // lint:allow(L003) — decoy: suppressed
    HashMap::new() // lint:allow(L003)
}
