//! Cross-layer integration tests: PJRT runtime ⇄ native mirror ⇄ MPC
//! protocols ⇄ coordinators, plus the real-TCP smoke test.
//!
//! These need `make artifacts` to have run; each test skips gracefully if
//! the artifacts directory is absent so `cargo test` stays green on a fresh
//! checkout (CI runs `make test` which builds artifacts first).

use spn_mpc::coordinator::infer::{private_eval, Query};
use spn_mpc::coordinator::train::{peek_weights, train, TrainConfig};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::runtime;
use spn_mpc::spn::structure::Structure;
use spn_mpc::spn::{eval, learn};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = runtime::default_artifacts_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn runtime_counts_match_native_mirror_all_datasets() {
    let Some(dir) = artifacts() else { return };
    let rt = runtime::Runtime::cpu().unwrap();
    for name in ["toy", "nltcs", "jester", "baudio", "bnetflix"] {
        let ds = runtime::load_dataset(&rt, &dir, name).unwrap();
        let st = &ds.structure;
        let gt = datasets::ground_truth_params(st, 3);
        let data = datasets::sample(st, &gt, 700, 99); // non-multiple of 512: tail masking
        let native = eval::counts(st, &data);
        let pjrt = ds.counts.counts(&data).unwrap();
        assert_eq!(native, pjrt, "{name}: artifact and native counts diverge");
    }
}

#[test]
fn runtime_eval_matches_native_logeval() {
    let Some(dir) = artifacts() else { return };
    let rt = runtime::Runtime::cpu().unwrap();
    let ds = runtime::load_dataset(&rt, &dir, "nltcs").unwrap();
    let st = &ds.structure;
    let gt = datasets::ground_truth_params(st, 4);
    let data = datasets::sample(st, &gt, 64, 5);
    let marg = vec![false; st.num_vars];
    let got = ds.eval.logeval(&data, &marg, &gt).unwrap();
    for (i, row) in data.iter().enumerate() {
        let want = eval::logeval(st, row, &marg, &gt);
        assert!(
            (got[i] - want).abs() < 1e-3,
            "row {i}: pjrt {} vs native {want}",
            got[i]
        );
    }
}

#[test]
fn full_pipeline_pjrt_counts_into_private_training() {
    let Some(dir) = artifacts() else { return };
    let rt = runtime::Runtime::cpu().unwrap();
    let ds = runtime::load_dataset(&rt, &dir, "toy").unwrap();
    let st = &ds.structure;
    let gt = datasets::ground_truth_params(st, 7);
    let data = datasets::sample(st, &gt, 1500, 42);
    let shards = datasets::partition(&data, 4);
    let counts: Vec<Vec<u64>> =
        shards.iter().map(|s| ds.counts.counts(s).unwrap()).collect();

    let mut eng = Engine::new(Field::paper(), EngineConfig::new(4));
    let (model, report) = train(&mut eng, st, &counts, 1500, &TrainConfig::default());
    assert_eq!(report.divisions, st.sum_groups.len());

    let oracle = learn::ml_weights_fixed(st, &eval::counts(st, &data), model.d);
    for (k, (&g, &o)) in peek_weights(&eng, &model).iter().zip(&oracle).enumerate() {
        assert!((g - o as i128).abs() <= 3, "param {k}");
    }
}

#[test]
fn training_then_inference_shares_flow() {
    let Some(dir) = artifacts() else { return };
    let st = Structure::load(dir.join("toy.structure.json")).unwrap();
    let gt = datasets::ground_truth_params(&st, 7);
    let data = datasets::sample(&st, &gt, 2000, 11);
    let shards = datasets::partition(&data, 5);
    let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(5).batched());
    let (model, _) = train(&mut eng, &st, &counts, 2000, &TrainConfig::default());
    let theta = learn::default_leaf_theta(&st);
    let q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
    let (root, _) = private_eval(&mut eng, &st, &model, &q, &theta);
    assert!((root - model.d as i128).abs() <= model.d as i128 / 8, "S(∅) ≈ 1");
}

#[test]
fn skewed_partition_still_exact() {
    // Eq. (3) holds for ANY horizontal partition — exactness is the paper's
    // core claim vs the §3.2 approximation.
    let Some(dir) = artifacts() else { return };
    let st = Structure::load(dir.join("toy.structure.json")).unwrap();
    let gt = datasets::ground_truth_params(&st, 8);
    let data = datasets::sample(&st, &gt, 3000, 12);
    let oracle = learn::ml_weights_fixed(&st, &eval::counts(&st, &data), 256);
    for skew in [0.5, 0.9] {
        let shards = datasets::partition_skewed(&data, 4, skew);
        let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(4).batched());
        let (model, _) = train(&mut eng, &st, &counts, 3000, &TrainConfig::default());
        for (k, (&g, &o)) in peek_weights(&eng, &model).iter().zip(&oracle).enumerate() {
            assert!((g - o as i128).abs() <= 3, "skew {skew} param {k}");
        }
    }
}

#[test]
fn member_count_does_not_change_result() {
    let Some(dir) = artifacts() else { return };
    let st = Structure::load(dir.join("toy.structure.json")).unwrap();
    let gt = datasets::ground_truth_params(&st, 9);
    let data = datasets::sample(&st, &gt, 1200, 13);
    let mut results = Vec::new();
    for n in [2usize, 3, 7, 13] {
        let shards = datasets::partition(&data, n);
        let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(n).batched());
        let (model, _) = train(&mut eng, &st, &counts, 1200, &TrainConfig::default());
        results.push(peek_weights(&eng, &model));
    }
    for w in &results[1..] {
        for (k, (&a, &b)) in results[0].iter().zip(w).enumerate() {
            assert!((a - b).abs() <= 3, "param {k} differs across member counts");
        }
    }
}

#[test]
fn tcp_transport_reveals_across_threads() {
    use spn_mpc::net::tcp;
    use spn_mpc::rng::Prng;
    use spn_mpc::sharing::additive::additive_share;
    use std::net::TcpListener;
    use std::thread;

    let f = Field::paper();
    let mut rng = Prng::seed_from_u64(77);
    let secret = 424_242u128;
    let shares = additive_share(&f, secret, 5, &mut rng);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let srv = thread::spawn(move || tcp::reveal_server_on(listener, 5, f.p).unwrap());
    let handles: Vec<_> = shares
        .into_iter()
        .enumerate()
        .map(|(i, sh)| {
            let a = addr.clone();
            thread::spawn(move || tcp::reveal_client(&a, i as u32, sh).unwrap())
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), secret);
    }
    assert_eq!(srv.join().unwrap(), secret);
}

#[test]
fn approx_and_exact_agree_on_iid_shards() {
    let Some(dir) = artifacts() else { return };
    use spn_mpc::coordinator::approx::{approx_divide, LocalFraction};
    use spn_mpc::net::NetConfig;
    let st = Structure::load(dir.join("toy.structure.json")).unwrap();
    let gt = datasets::ground_truth_params(&st, 10);
    let data = datasets::sample(&st, &gt, 6000, 14);
    let shards = datasets::partition(&data, 3);
    let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();

    let mut params_in = Vec::new();
    for k in 0..st.num_sum_edges {
        params_in.push(
            (0..3)
                .map(|i| LocalFraction {
                    num: counts[i][st.param_num[k]],
                    den: counts[i][st.param_den[k]],
                })
                .collect::<Vec<_>>(),
        );
    }
    let approx = approx_divide(&Field::paper(), &params_in, 256, NetConfig::default(), 5);

    let mut eng = Engine::new(Field::paper(), EngineConfig::new(3).batched());
    let (model, _) = train(&mut eng, &st, &counts, 6000, &TrainConfig::default());
    let exact = peek_weights(&eng, &model);
    for k in 0..st.num_sum_edges {
        let a = approx.revealed[k] as i128;
        let e = exact[k];
        assert!((a - e).abs() <= 12, "param {k}: approx {a} exact {e}");
    }
}
