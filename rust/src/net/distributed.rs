//! Distributed Manager/Member session over real TCP sockets — the smoke-
//! scale deployment of the exercise protocol (§5.2 / Appendix A).
//!
//! Each member runs in its own thread with its own private store and RNG
//! and talks TCP to the Manager; exercises are broadcast as frames and the
//! members' sub-share exchanges are *relayed* through the Manager (the
//! paper's WebSocket topology also stars at the Manager).  The relay only
//! ever sees Shamir sub-shares, but a malicious-manager deployment should
//! use the pairwise mesh (`tcp::Frame` supports arbitrary endpoints); this
//! module is the transport smoke test, while `SimNet` carries the paper's
//! exact accounting.
//!
//! Supported exercises: Input, Mul (BGW resharing), DivPub (§3.4), Reveal.
//! That is exactly the vocabulary one private division needs, so the
//! integration test runs a real `⌊a·b/d⌋` across 5 OS threads.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::tcp::{read_frame, write_frame, Frame};
use crate::field::Field;
use crate::rng::{Prng, Rng};
use crate::sharing::shamir::ShamirCtx;

// Opcodes (first element of an exercise frame).
const OP_INPUT: u128 = 1;
const OP_MUL: u128 = 2;
const OP_DIVPUB: u128 = 3;
const OP_REVEAL: u128 = 4;
const OP_SHUTDOWN: u128 = 5;

/// One member's event loop: connect, then serve exercises until shutdown.
fn member_loop(
    addr: String,
    id: usize, // 1-based
    n: usize,
    field: Field,
    private_inputs: Vec<u128>,
    seed: u64,
) -> Result<()> {
    let shamir = ShamirCtx::new(field, n);
    let mut rng = Prng::seed_from_u64(seed ^ (id as u64) << 17);
    let mut store: HashMap<u128, u128> = HashMap::new();
    let mut s = TcpStream::connect(&addr)?;
    write_frame(&mut s, &Frame { exercise_id: 0, from: id as u32, elems: vec![] })?;

    loop {
        let ex = read_frame(&mut s)?;
        let op = ex.elems[0];
        match op {
            OP_SHUTDOWN => return Ok(()),
            OP_INPUT => {
                // [op, out, owner, input_idx]
                let (out, owner, idx) = (ex.elems[1], ex.elems[2] as usize, ex.elems[3] as usize);
                if owner == id {
                    let shares = shamir.share(private_inputs[idx] % field.p, &mut rng);
                    write_frame(
                        &mut s,
                        &Frame { exercise_id: ex.exercise_id, from: id as u32, elems: shares },
                    )?;
                }
                // everyone receives their share from the relay
                let f = read_frame(&mut s)?;
                store.insert(out, f.elems[0]);
            }
            OP_MUL => {
                // [op, out, a, b]: local product -> deal -> combine
                let (out, a, b) = (ex.elems[1], ex.elems[2], ex.elems[3]);
                let z = field.mul(store[&a], store[&b]);
                let sub = shamir.share(z, &mut rng);
                write_frame(
                    &mut s,
                    &Frame { exercise_id: ex.exercise_id, from: id as u32, elems: sub },
                )?;
                // relay returns the n sub-shares destined to me
                let f = read_frame(&mut s)?;
                let lambda = shamir.lambda();
                let mut acc = 0u128;
                for (i, &ss) in f.elems.iter().enumerate() {
                    acc = field.add(acc, field.mul(lambda[i], ss));
                }
                store.insert(out, acc);
            }
            OP_DIVPUB => {
                // [op, out, u, d]; Alice = member 1, Bob = member 2
                let (out, u, d) = (ex.elems[1], ex.elems[2], ex.elems[3]);
                if id == 1 {
                    let r = rng.gen_bits(64);
                    let q = r % d;
                    let mut elems = shamir.share(r, &mut rng);
                    elems.extend(shamir.share(q, &mut rng));
                    write_frame(
                        &mut s,
                        &Frame { exercise_id: ex.exercise_id, from: id as u32, elems },
                    )?;
                }
                let f = read_frame(&mut s)?; // my [r]_i, [q]_i
                let (r_i, q_i) = (f.elems[0], f.elems[1]);
                // z' = u + r opened to Bob (via relay)
                let z_i = field.add(store[&u], r_i);
                write_frame(
                    &mut s,
                    &Frame { exercise_id: ex.exercise_id, from: id as u32, elems: vec![z_i] },
                )?;
                if id == 2 {
                    let f = read_frame(&mut s)?; // all z' shares
                    let z = shamir.reconstruct(&f.elems);
                    let w = z % d;
                    write_frame(
                        &mut s,
                        &Frame { exercise_id: ex.exercise_id, from: id as u32, elems: shamir.share(w, &mut rng) },
                    )?;
                }
                let f = read_frame(&mut s)?; // my [w]_i
                let w_i = f.elems[0];
                let dinv = field.inv(d % field.p);
                let v = field.mul(field.sub(field.add(store[&u], q_i), w_i), dinv);
                store.insert(out, v);
            }
            OP_REVEAL => {
                // [op, a]: send my share to the manager
                let a = ex.elems[1];
                write_frame(
                    &mut s,
                    &Frame { exercise_id: ex.exercise_id, from: id as u32, elems: vec![store[&a]] },
                )?;
            }
            _ => return Err(anyhow!("member {id}: unknown opcode {op}")),
        }
    }
}

/// The Manager: owns the listener, schedules exercises, relays sub-shares.
pub struct Manager {
    n: usize,
    field: Field,
    shamir: ShamirCtx,
    conns: Vec<TcpStream>, // index i = member i+1
    next_ex: u64,
    next_id: u128,
    pub handles: Vec<JoinHandle<Result<()>>>,
}

impl Manager {
    /// Spawn `n` member threads with the given private inputs and connect
    /// them to an ephemeral local port.
    pub fn spawn_local(field: Field, inputs: Vec<Vec<u128>>, seed: u64) -> Result<Self> {
        let n = inputs.len();
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let mut handles = Vec::new();
        for (i, inp) in inputs.into_iter().enumerate() {
            let a = addr.clone();
            handles.push(std::thread::spawn(move || {
                member_loop(a, i + 1, n, field, inp, seed)
            }));
        }
        let mut conns_by_id: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (mut s, _) = listener.accept()?;
            let hello = read_frame(&mut s)?;
            conns_by_id[hello.from as usize - 1] = Some(s);
        }
        let conns: Vec<TcpStream> = conns_by_id.into_iter().map(|c| c.unwrap()).collect();
        Ok(Manager {
            n,
            field,
            shamir: ShamirCtx::new(field, n),
            conns,
            next_ex: 0,
            next_id: 0,
            handles,
        })
    }

    fn broadcast(&mut self, elems: Vec<u128>) -> Result<u64> {
        self.next_ex += 1;
        let ex = self.next_ex;
        for s in self.conns.iter_mut() {
            write_frame(s, &Frame { exercise_id: ex, from: u32::MAX, elems: elems.clone() })?;
        }
        Ok(ex)
    }

    fn alloc(&mut self) -> u128 {
        self.next_id += 1;
        self.next_id
    }

    /// Schedule: owner deals shares of its `idx`-th private input.
    pub fn input(&mut self, owner: usize, idx: usize) -> Result<u128> {
        let out = self.alloc();
        let ex = self.broadcast(vec![OP_INPUT, out, owner as u128, idx as u128])?;
        let dealt = read_frame(&mut self.conns[owner - 1])?.elems;
        for (j, s) in self.conns.iter_mut().enumerate() {
            write_frame(s, &Frame { exercise_id: ex, from: owner as u32, elems: vec![dealt[j]] })?;
        }
        Ok(out)
    }

    /// Schedule a secure multiplication; relays the resharing mesh.
    pub fn mul(&mut self, a: u128, b: u128) -> Result<u128> {
        let out = self.alloc();
        let ex = self.broadcast(vec![OP_MUL, out, a, b])?;
        // collect each member's dealt vector, transpose, redistribute
        let mut dealt = Vec::with_capacity(self.n);
        for s in self.conns.iter_mut() {
            dealt.push(read_frame(s)?.elems);
        }
        for (j, s) in self.conns.iter_mut().enumerate() {
            let col: Vec<u128> = (0..self.n).map(|i| dealt[i][j]).collect();
            write_frame(s, &Frame { exercise_id: ex, from: u32::MAX, elems: col })?;
        }
        Ok(out)
    }

    /// Schedule a §3.4 division-by-public.
    pub fn divpub(&mut self, u: u128, d: u128) -> Result<u128> {
        let out = self.alloc();
        let ex = self.broadcast(vec![OP_DIVPUB, out, u, d])?;
        // phase 1: Alice dealt [r] ++ [q]; forward per member
        let alice = read_frame(&mut self.conns[0])?.elems;
        let n = self.n;
        for (j, s) in self.conns.iter_mut().enumerate() {
            write_frame(
                s,
                &Frame { exercise_id: ex, from: 1, elems: vec![alice[j], alice[n + j]] },
            )?;
        }
        // phase 2: collect z' shares, hand them to Bob
        let mut zs = Vec::with_capacity(n);
        for s in self.conns.iter_mut() {
            zs.push(read_frame(s)?.elems[0]);
        }
        write_frame(&mut self.conns[1], &Frame { exercise_id: ex, from: u32::MAX, elems: zs })?;
        // phase 3: Bob dealt [w]; forward per member
        let bob = read_frame(&mut self.conns[1])?.elems;
        for (j, s) in self.conns.iter_mut().enumerate() {
            write_frame(s, &Frame { exercise_id: ex, from: 2, elems: vec![bob[j]] })?;
        }
        Ok(out)
    }

    /// Reveal a shared value to the manager.
    pub fn reveal(&mut self, a: u128) -> Result<u128> {
        self.broadcast(vec![OP_REVEAL, a])?;
        let mut shares = Vec::with_capacity(self.n);
        for s in self.conns.iter_mut() {
            shares.push(read_frame(s)?.elems[0]);
        }
        Ok(self.shamir.reconstruct(&shares))
    }

    /// Stop all members and join their threads.
    pub fn shutdown(mut self) -> Result<()> {
        self.broadcast(vec![OP_SHUTDOWN])?;
        for h in self.handles.drain(..) {
            h.join().map_err(|_| anyhow!("member thread panicked"))??;
        }
        Ok(())
    }

    pub fn signed(&self, v: u128) -> i128 {
        self.field.to_i128(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributed_mul_and_divpub_over_tcp() {
        let field = Field::paper();
        // member 1 holds 123, member 2 holds 45; others have no inputs
        let inputs = vec![vec![123u128], vec![45u128], vec![], vec![], vec![]];
        let mut mgr = Manager::spawn_local(field, inputs, 0xBEEF).unwrap();
        let a = mgr.input(1, 0).unwrap();
        let b = mgr.input(2, 0).unwrap();
        let ab = mgr.mul(a, b).unwrap();
        assert_eq!(mgr.reveal(ab).unwrap(), 123 * 45);
        // ⌊123·45/256⌋ = 21, ±1 protocol error
        let q = mgr.divpub(ab, 256).unwrap();
        let got = {
            let v = mgr.reveal(q).unwrap();
            mgr.signed(v)
        };
        assert!((got - 21).abs() <= 1, "got {got}");
        mgr.shutdown().unwrap();
    }

    #[test]
    fn distributed_three_members_chain() {
        let field = Field::paper();
        let inputs = vec![vec![7u128], vec![8u128], vec![9u128]];
        let mut mgr = Manager::spawn_local(field, inputs, 0xCAFE).unwrap();
        let a = mgr.input(1, 0).unwrap();
        let b = mgr.input(2, 0).unwrap();
        let c = mgr.input(3, 0).unwrap();
        let ab = mgr.mul(a, b).unwrap();
        let abc = mgr.mul(ab, c).unwrap();
        assert_eq!(mgr.reveal(abc).unwrap(), 7 * 8 * 9);
        mgr.shutdown().unwrap();
    }
}
