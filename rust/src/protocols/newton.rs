//! Progressive-precision Newton inverse of a secret-shared denominator
//! (§3.4) — the paper's main protocol.
//!
//! Given polynomial shares `[b]` of an integer `1 ≤ b ≤ bmax` and the public
//! normalization `d`, compute shares `[u] ≈ d·E/b` for a public final scale
//! `E`, using only secure multiplications and divisions-by-public.
//!
//! Differences from Algesheimer–Camenisch–Shoup [14] that the paper claims
//! (and we implement):
//!  * no representation conversion — everything stays in polynomial shares;
//!  * no initial guess `d/2b ≤ u ≤ d/b` is needed: start from `u = 1`
//!    (an *under*estimate) and run `⌈log₂ D₀⌉ (+t)` warm-up iterations —
//!    since `f_{i+1} = f_i²/(2f_i − 1)` halves the exponent of `f = D/(b·u)`
//!    each step, `f ≤ 2` after `⌈log₂ D₀⌉` steps (paper §3.4);
//!  * per-iteration precision doubling thereafter (`u ← u(2 − ub/(d·e))`,
//!    `e ← 2e`) for `n = 16` refinement rounds (paper §5.3).
//!
//! We add `g` guard bits to the iteration (scale the quotient by `G = 2^g`
//! before the division-by-public and divide back after), which keeps the
//! ±1 rounding of each divpub at relative size `2⁻ᵍ` instead of `1/f` —
//! without this the iteration can oscillate or collapse to 0 near
//! convergence (`s = 2` exactly makes `u(2−s) = 0`).  This is our
//! implementation refinement of the same protocol; the ablation bench
//! `ablation_newton` sweeps `g`, including the paper-literal `g = 0`.

use super::engine::DataId;
use super::session::MpcSession;
use crate::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct NewtonConfig {
    /// Normalization factor (paper: d = 256).
    pub d: u128,
    /// Refinement (precision-doubling) iterations (paper: n = 16).
    pub refine_iters: u32,
    /// Extra warm-up guard iterations (paper: t = 5).
    pub t_extra: u32,
    /// Guard bits for the in-iteration divisions (0 = paper-literal).
    pub guard_bits: u32,
}

impl Default for NewtonConfig {
    fn default() -> Self {
        NewtonConfig { d: 256, refine_iters: 16, t_extra: 5, guard_bits: 10 }
    }
}

fn pow2_ceil(x: u128) -> u128 {
    x.max(1).next_power_of_two()
}

fn ceil_log2(x: u128) -> u32 {
    assert!(x >= 1);
    128 - (x - 1).leading_zeros()
}

/// Public schedule derived from (d, bmax): initial scale, warmup count and
/// the final scale E. Everything here is public information.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NewtonPlan {
    pub e0: u128,
    pub d0: u128,
    pub warmup: u32,
    pub refine: u32,
    pub final_scale: u128, // E = e0 << refine
}

pub fn plan(cfg: &NewtonConfig, bmax: u128) -> NewtonPlan {
    assert!(cfg.d >= 2 && bmax >= 1);
    // D0 = d*e0 must exceed bmax so u=1 underestimates D0/b.
    let e0 = pow2_ceil((2 * bmax).div_ceil(cfg.d));
    let d0 = cfg.d * e0;
    let warmup = ceil_log2(d0) + cfg.t_extra;
    let refine = cfg.refine_iters;
    let final_scale = e0 << refine;
    // Overflow budget: the largest divpub input is u*b*G ≤ 2^62 (see
    // divpub security note). u ≤ 2·d·E, b ≤ bmax, G = 2^g. This bound
    // assumes b ≥ 1 — the training coordinator guarantees it by +1
    // (Laplace) smoothing of denominators; for b = 0 the value u grows to
    // at most 2^(warmup + 2·refine), which stays below the masking window
    // but erodes its slack (documented degenerate case).
    let u_bits = 128 - (2 * cfg.d * final_scale).leading_zeros();
    let b_bits = 128 - bmax.leading_zeros();
    assert!(
        u_bits + b_bits + cfg.guard_bits <= 62,
        "Newton overflow budget exceeded: u={u_bits}b b={b_bits}b g={}",
        cfg.guard_bits
    );
    NewtonPlan { e0, d0, warmup, refine, final_scale }
}

/// Plaintext mirror of the protocol: identical integer arithmetic, with the
/// same divpub randomness model. Returns (u ≈ d·E/b, plan).
pub fn newton_plain<R: Rng + ?Sized>(
    b: u128,
    bmax: u128,
    cfg: &NewtonConfig,
    rho_bits: u32,
    rng: &mut R,
) -> (i128, NewtonPlan) {
    let pl = plan(cfg, bmax);
    let g = 1i128 << cfg.guard_bits;
    let mut u: i128 = 1;
    let mut dscale = pl.d0 as i128;
    for it in 0..(pl.warmup + pl.refine) {
        if it >= pl.warmup {
            dscale *= 2;
            u *= 2;
        }
        let t = u * b as i128;
        let s = super::divpub::divpub_plain((t * g) as u128, dscale as u128,
                                            super::divpub::sample_r(rng, rho_bits));
        let v = u * (2 * g - s);
        u = super::divpub::divpub_plain(v.max(0) as u128, g as u128,
                                        super::divpub::sample_r(rng, rho_bits));
    }
    (u, pl)
}

/// The secure protocol over any [`MpcSession`] backend (the simulated
/// engine or real TCP parties). `[b]` must hold an integer in `[0, bmax]`;
/// returns `([u], plan)` with `u ≈ d·E/b` (u is the shared approximate
/// inverse, E = plan.final_scale; for b = 0 the result is a bounded garbage
/// value that multiplies to 0 weights downstream).
pub fn newton_inverse<S: MpcSession>(sess: &mut S, b: DataId, bmax: u128, cfg: &NewtonConfig)
    -> (DataId, NewtonPlan) {
    let (us, pl) = newton_inverse_vec(sess, &[b], bmax, cfg);
    (us[0], pl)
}

/// Vectorized [`newton_inverse`]: invert many shared denominators at once.
///
/// All of them share one public schedule (same `bmax` ⇒ same warm-up and
/// refinement counts), so the k inversions advance in lockstep: each
/// iteration issues *one* `mul_vec`/`lin_vec`/`divpub_vec` sweep over every
/// denominator instead of k separate sweeps. Under the `Batched` schedule
/// (and over real TCP) the iteration's communication rounds are therefore
/// paid once for the whole vector — the rounds-amortization that makes
/// training cost scale with the iteration count, not `k ×` it. For `k = 1`
/// the call sequence (and with it accounting *and* RNG draw order) is
/// identical to the scalar [`newton_inverse`].
pub fn newton_inverse_vec<S: MpcSession>(
    sess: &mut S,
    bs: &[DataId],
    bmax: u128,
    cfg: &NewtonConfig,
) -> (Vec<DataId>, NewtonPlan) {
    let pl = plan(cfg, bmax);
    let k = bs.len();
    if k == 0 {
        return (Vec::new(), pl);
    }
    let g = 1i128 << cfg.guard_bits;
    let one = sess.constant(1);
    let mut us = vec![one; k];
    let mut dscale = pl.d0;
    for it in 0..(pl.warmup + pl.refine) {
        if it >= pl.warmup {
            dscale *= 2;
            let ops: Vec<(i128, Vec<(i128, DataId)>)> =
                us.iter().map(|&u| (0, vec![(2, u)])).collect();
            us = sess.lin_vec(&ops);
        }
        let pairs: Vec<(DataId, DataId)> = us.iter().copied().zip(bs.iter().copied()).collect();
        let ts = sess.mul_vec(&pairs);
        let tg_ops: Vec<(i128, Vec<(i128, DataId)>)> =
            ts.iter().map(|&t| (0, vec![(g, t)])).collect();
        let tgs = sess.lin_vec(&tg_ops);
        let ss = sess.divpub_vec(&tgs, dscale);
        let corr_ops: Vec<(i128, Vec<(i128, DataId)>)> =
            ss.iter().map(|&s| (2 * g, vec![(-1, s)])).collect();
        let corrs = sess.lin_vec(&corr_ops);
        let v_pairs: Vec<(DataId, DataId)> =
            us.iter().copied().zip(corrs.iter().copied()).collect();
        let vs = sess.mul_vec(&v_pairs);
        us = sess.divpub_vec(&vs, g as u128);
    }
    (us, pl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::Field;
    use crate::protocols::engine::{Engine, EngineConfig};
    use crate::rng::Prng;

    fn close(u: i128, b: u128, pl: &NewtonPlan, d: u128) -> bool {
        let want = (d * pl.final_scale / b) as i128;
        let tol = (want / 64).max(4); // ≤ ~1.6% relative + small absolute
        (u - want).abs() <= tol
    }

    #[test]
    fn plain_converges_across_b_range() {
        let cfg = NewtonConfig::default();
        let mut rng = Prng::seed_from_u64(1);
        let bmax = 16384u128;
        for b in [1u128, 2, 3, 10, 100, 255, 256, 1000, 9999, 16000, 16384] {
            let (u, pl) = newton_plain(b, bmax, &cfg, 64, &mut rng);
            assert!(close(u, b, &pl, cfg.d), "b={b}: u={u} want={}", cfg.d * pl.final_scale / b);
        }
    }

    #[test]
    fn plain_handles_b_zero_bounded() {
        // b = 0 is degenerate (coordinator +1-smooths it away); the value
        // must stay non-negative and below 2^(warmup + 2·refine) + slack so
        // nothing wraps mod p.
        let cfg = NewtonConfig::default();
        let mut rng = Prng::seed_from_u64(2);
        let (u, pl) = newton_plain(0, 1000, &cfg, 64, &mut rng);
        let bound = 1i128 << (pl.warmup + 2 * pl.refine + 2);
        assert!(u >= 0 && u <= bound, "u={u} bound={bound}");
    }

    #[test]
    fn warmup_count_matches_paper_analysis() {
        // ⌈log₂ D₀⌉ warmup: for d=256, bmax=16181 → e0=128, D0=2^15,
        // warmup = 15 + t_extra.
        let cfg = NewtonConfig::default();
        let pl = plan(&cfg, 16181);
        assert_eq!(pl.e0, 128);
        assert_eq!(pl.d0, 1 << 15);
        assert_eq!(pl.warmup, 15 + cfg.t_extra);
        assert_eq!(pl.final_scale, 128 << 16);
    }

    #[test]
    fn protocol_matches_quality_of_plain() {
        let cfg = NewtonConfig::default();
        let bmax = 2000u128;
        for n in [3usize, 5] {
            let mut eng = Engine::new(Field::paper(), EngineConfig::new(n));
            for b in [1u128, 7, 256, 1999] {
                let bid = eng.input(1, &[b])[0];
                let (uid, pl) = newton_inverse(&mut eng, bid, bmax, &cfg);
                let u = eng.peek_int(uid);
                assert!(close(u, b, &pl, cfg.d), "n={n} b={b}: u={u}");
            }
        }
    }

    #[test]
    fn vectorized_inverse_accurate_and_round_amortized() {
        let cfg = NewtonConfig::default();
        let bmax = 2000u128;
        let bs = [3u128, 77, 500, 1999];

        // Vectorized: all four inversions in lockstep.
        let mut vec_eng = Engine::new(Field::paper(), EngineConfig::new(5).batched());
        let ids = vec_eng.input(1, &bs);
        let before = vec_eng.net.stats;
        let (invs, pl) = newton_inverse_vec(&mut vec_eng, &ids, bmax, &cfg);
        let vec_rounds = vec_eng.net.stats.delta_since(&before).rounds;
        for (&b, &id) in bs.iter().zip(&invs) {
            let u = vec_eng.peek_int(id);
            assert!(close(u, b, &pl, cfg.d), "vec b={b}: u={u}");
        }

        // Sequential: four scalar inversions on an identical engine.
        let mut seq_eng = Engine::new(Field::paper(), EngineConfig::new(5).batched());
        let ids = seq_eng.input(1, &bs);
        let before = seq_eng.net.stats;
        for &id in &ids {
            let _ = newton_inverse(&mut seq_eng, id, bmax, &cfg);
        }
        let seq_rounds = seq_eng.net.stats.delta_since(&before).rounds;
        assert!(
            vec_rounds * 3 < seq_rounds,
            "lockstep iterations must amortize rounds: vec {vec_rounds} vs seq {seq_rounds}"
        );
    }

    #[test]
    fn vectorized_with_one_denominator_equals_scalar() {
        let cfg = NewtonConfig::default();
        let mut a = Engine::new(Field::paper(), EngineConfig::new(3));
        let ba = a.input(1, &[77])[0];
        let (ua, _) = newton_inverse(&mut a, ba, 1000, &cfg);
        let mut b = Engine::new(Field::paper(), EngineConfig::new(3));
        let bb = b.input(1, &[77])[0];
        let (ub, _) = newton_inverse_vec(&mut b, &[bb], 1000, &cfg);
        assert_eq!(a.peek_int(ua), b.peek_int(ub[0]), "k=1 must be the scalar protocol");
        assert_eq!(a.net.stats, b.net.stats, "k=1 must also account identically");
    }

    #[test]
    fn paper_literal_g0_can_collapse_guard_bits_fix_it() {
        // g=0 (paper-literal iteration): the ±1 divpub rounding can make
        // s = 2 exactly at convergence, collapsing u(2−s) to 0 — this is
        // precisely why we add guard bits. The ablation_newton bench
        // quantifies the error distribution across g.
        let bmax = 1000u128;
        let mut collapsed_g0 = 0;
        let mut bad_g10 = 0;
        for b in 1..=100u128 {
            let cfg0 = NewtonConfig { guard_bits: 0, ..NewtonConfig::default() };
            let mut rng = Prng::seed_from_u64(3 + b as u64);
            let (u0, pl) = newton_plain(b, bmax, &cfg0, 64, &mut rng);
            let want = (cfg0.d * pl.final_scale / b) as f64;
            assert!(u0 >= 0, "g=0 must stay non-negative");
            if ((u0 as f64) - want).abs() / want.max(1.0) > 0.5 {
                collapsed_g0 += 1;
            }
            let cfg10 = NewtonConfig::default();
            let (u1, pl1) = newton_plain(b, bmax, &cfg10, 64, &mut rng);
            if !close(u1, b, &pl1, cfg10.d) {
                bad_g10 += 1;
            }
        }
        assert!(collapsed_g0 > 0, "expected g=0 to show collapses");
        assert_eq!(bad_g10, 0, "g=10 must be uniformly accurate");
    }

    #[test]
    fn prop_plain_accuracy() {
        let cfg = NewtonConfig::default();
        crate::rng::property(64, |rng| {
            let b = 1 + rng.gen_range_u128(15999);
            let (u, pl) = newton_plain(b, 16000, &cfg, 64, rng);
            assert!(close(u, b, &pl, cfg.d), "b={} u={}", b, u);
        });
    }
}
