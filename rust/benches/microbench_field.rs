//! L3 microbenchmarks: the field/share/protocol primitives on the hot path.
//! This is the §Perf instrument — run before/after optimization.

use spn_mpc::bench::{throughput, time_it, JsonSink};
use spn_mpc::field::Field;
use spn_mpc::metrics::render_table;
use spn_mpc::protocols::division::{private_divide, DivisionConfig};
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::rng::Prng;
use spn_mpc::sharing::shamir::ShamirCtx;

fn main() {
    let mut json = JsonSink::from_env_args();
    let f = Field::paper();
    let mut rng = Prng::seed_from_u64(1);
    let xs: Vec<u128> = (0..4096).map(|_| f.rand(&mut rng)).collect();
    let ys: Vec<u128> = (0..4096).map(|_| f.rand(&mut rng)).collect();

    let mut rows = Vec::new();

    let s = time_it(3, 20, || {
        let mut acc = 0u128;
        for (&a, &b) in xs.iter().zip(&ys) {
            acc = f.add(acc, f.mul(a, b));
        }
        acc
    });
    rows.push(vec![
        "field mulmod (74-bit)".into(),
        format!("{:.1} M ops/s", throughput(&s, 4096) / 1e6),
        s.per_iter_str(),
    ]);
    json.push("microbench_field", "mulmod_mops", throughput(&s, 4096) / 1e6);

    let s = time_it(3, 20, || {
        let mut acc = 0u128;
        for (&a, &b) in xs.iter().zip(&ys) {
            acc = f.add(acc, f.sub(a, b));
        }
        acc
    });
    rows.push(vec![
        "field add/sub".into(),
        format!("{:.1} M ops/s", throughput(&s, 8192) / 1e6),
        s.per_iter_str(),
    ]);
    json.push("microbench_field", "addsub_mops", throughput(&s, 8192) / 1e6);

    let s = time_it(2, 10, || f.inv(xs[0]));
    rows.push(vec!["field inverse (Fermat)".into(), String::new(), s.per_iter_str()]);
    json.push("microbench_field", "inverse_ns", s.mean_s * 1e9);

    // Montgomery kernel rows (§Perf iteration 7): the REDC multiply with
    // one canonical operand (the hot dealing/recombination shape), the
    // domain round-trip, and the deferred-reduction dot against the naive
    // mul/add fold it replaced.
    let ys_mont: Vec<u128> = ys.iter().map(|&y| f.to_mont(y)).collect();
    let s = time_it(3, 20, || {
        let mut acc = 0u128;
        for (&a, &bm) in xs.iter().zip(&ys_mont) {
            acc = f.mont_mul_add(acc, a, bm);
        }
        acc
    });
    rows.push(vec![
        "field mont_mul_add (REDC)".into(),
        format!("{:.1} M ops/s", throughput(&s, 4096) / 1e6),
        s.per_iter_str(),
    ]);
    json.push("microbench_field", "mont_mul_ns", s.mean_s * 1e9 / 4096.0);

    let s = time_it(3, 20, || {
        let mut acc = 0u128;
        for &a in &xs {
            acc ^= f.from_mont(f.to_mont(a));
        }
        acc
    });
    rows.push(vec![
        "field to_mont∘from_mont".into(),
        format!("{:.1} M ops/s", throughput(&s, 4096) / 1e6),
        s.per_iter_str(),
    ]);
    json.push("microbench_field", "to_from_mont_ns", s.mean_s * 1e9 / 4096.0);

    let s_def = time_it(3, 20, || f.dot(&xs, &ys));
    let s_naive = time_it(3, 20, || {
        let mut acc = 0u128;
        for (&a, &b) in xs.iter().zip(&ys) {
            acc = f.add(acc, f.mul(a, b));
        }
        acc
    });
    let speedup = s_naive.mean_s / s_def.mean_s;
    rows.push(vec![
        "field dot (deferred vs naive)".into(),
        format!("{speedup:.2}× vs naive fold"),
        s_def.per_iter_str(),
    ]);
    json.push("microbench_field", "dot_deferred_vs_naive", speedup);

    for n in [5usize, 13] {
        let ctx = ShamirCtx::new(f, n);
        let mut rng = Prng::seed_from_u64(2);
        let s = time_it(2, 50, || ctx.share(12345, &mut rng));
        rows.push(vec![format!("shamir share (n={n})"), String::new(), s.per_iter_str()]);
        json.push("microbench_field", &format!("share_n{n}_ns"), s.mean_s * 1e9);
        let sh = ctx.share(12345, &mut rng);
        let s = time_it(2, 200, || ctx.reconstruct(&sh));
        rows.push(vec![format!("shamir reconstruct (n={n})"), String::new(), s.per_iter_str()]);
        json.push("microbench_field", &format!("reconstruct_n{n}_ns"), s.mean_s * 1e9);
    }

    for n in [5usize, 13] {
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(n));
        let a = eng.input(1, &[123])[0];
        let b = eng.input(2, &[456])[0];
        let s = time_it(2, 50, || eng.mul(a, b));
        rows.push(vec![format!("engine secure mul (n={n})"), String::new(), s.per_iter_str()]);
        json.push("microbench_field", &format!("secure_mul_n{n}_us"), s.mean_s * 1e6);
        let s = time_it(1, 20, || eng.divpub(a, 256));
        rows.push(vec![format!("engine divpub (n={n})"), String::new(), s.per_iter_str()]);
        json.push("microbench_field", &format!("divpub_n{n}_us"), s.mean_s * 1e6);
        let num = eng.input(1, &[600])[0];
        let den = eng.input(1, &[2169])[0];
        let s = time_it(1, 5, || private_divide(&mut eng, num, den, 4096, &DivisionConfig::default()));
        rows.push(vec![
            format!("full private division (n={n})"),
            String::new(),
            s.per_iter_str(),
        ]);
        json.push("microbench_field", &format!("private_division_n{n}_ms"), s.mean_s * 1e3);
    }

    println!(
        "{}",
        render_table("L3 primitive microbenchmarks", &["primitive", "throughput", "latency"], &rows)
    );
    json.finish().expect("write --json output");
    println!("microbench_field OK");
}
