//! Batched layered evaluation in rust — the plaintext mirror of the AOT
//! artifacts (counts and log-eval).
//!
//! On the request path the PJRT runtime executes the HLO artifacts; this
//! module provides the same semantics in portable rust for (a) cross-checks
//! between the two implementations (integration test
//! `runtime_matches_native`), (b) environments without artifacts, and
//! (c) the centralized "oracle" training used to verify the MPC result.

use super::structure::{LayerKind, Structure};

/// Bottom-up positivity for one instance: 1.0/0.0 per node, layer by layer
/// (leaf gate claims, product AND, sum OR). Returns per-layer vectors with
/// layer 0 = leaves.
pub fn positivity(st: &Structure, x: &[u8]) -> Vec<Vec<f64>> {
    let w0 = st.num_leaves();
    let mut pos_leaf = vec![0.0; w0];
    for i in 0..w0 {
        let claim = st.leaf_claim[i];
        pos_leaf[i] = if claim < 0 || x[st.leaf_var[i]] as i64 == claim { 1.0 } else { 0.0 };
    }
    let mut out = vec![pos_leaf];
    for (li, l) in st.layers.iter().enumerate() {
        let prev_w = if li > 0 { st.layer_widths[li] } else { 0 };
        let mut acc = vec![0.0f64; l.width];
        let mut deg = vec![0usize; l.width];
        for (&r, &c) in l.rows.iter().zip(&l.cols) {
            let v = if c < prev_w { out[li][c] } else { out[0][c - prev_w] };
            match l.kind {
                LayerKind::Product => {
                    deg[r] += 1;
                    acc[r] += v;
                }
                LayerKind::Sum => acc[r] = f64::max(acc[r], v),
            }
        }
        if l.kind == LayerKind::Product {
            for r in 0..l.width {
                acc[r] = if acc[r] >= deg[r] as f64 - 0.5 { 1.0 } else { 0.0 };
            }
        }
        out.push(acc);
    }
    out
}

/// Top-down activation from the bottom-up positivity (tree semantics:
/// act(child) = act(parent) AND pos(child); root act = pos(root)).
/// Returns (per-layer activations incl. layer 0 = leaves).
pub fn activation(st: &Structure, pos: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let nl = st.layers.len();
    let mut act: Vec<Vec<f64>> = st.layer_widths.iter().map(|&w| vec![0.0; w]).collect();
    act[nl] = pos[nl].clone();
    for li in (0..nl).rev() {
        let l = &st.layers[li];
        let prev_w = if li > 0 { st.layer_widths[li] } else { 0 };
        // Split the layer stack so the parent layer (read) and the two
        // destination layers (written: act[li] for Prev-children, act[0]
        // for leaves) borrow disjoint regions — no per-row clone.
        let (lower, upper) = act.split_at_mut(li + 1);
        let parent: &[f64] = &upper[0];
        let (leaf_act, mid) = lower.split_first_mut().expect("layer 0 always exists");
        for (&r, &c) in l.rows.iter().zip(&l.cols) {
            let down = parent[r];
            if c < prev_w {
                // prev_w > 0 implies li > 0, so act[li] = mid[li - 1]
                let dst = &mut mid[li - 1][c];
                let v = down * pos[li][c];
                if v > *dst {
                    *dst = v;
                }
            } else {
                let lf = c - prev_w;
                let v = down * pos[0][lf];
                if v > leaf_act[lf] {
                    leaf_act[lf] = v;
                }
            }
        }
    }
    act
}

/// The counts vector over a dataset shard: activation counts for all nodes
/// (leaves then each layer) followed by `act ∧ (x_v = 1)` counts per leaf —
/// byte-for-byte the artifact's output semantics.
pub fn counts(st: &Structure, data: &[Vec<u8>]) -> Vec<u64> {
    let w0 = st.num_leaves();
    let mut cnt = vec![0u64; st.counts_len()];
    for x in data {
        let pos = positivity(st, x);
        let act = activation(st, &pos);
        let mut off = 0usize;
        for layer_act in &act {
            for (i, &a) in layer_act.iter().enumerate() {
                if a > 0.5 {
                    cnt[off + i] += 1;
                }
            }
            off += layer_act.len();
        }
        for i in 0..w0 {
            if act[0][i] > 0.5 && x[st.leaf_var[i]] == 1 {
                cnt[st.total_nodes + i] += 1;
            }
        }
    }
    cnt
}

/// Log-domain evaluation of one instance given parameters in [0,1]
/// (sum weights then leaf thetas, matching the artifact's input layout).
/// `marg[v] = true` marginalizes variable v.
pub fn logeval(st: &Structure, x: &[u8], marg: &[bool], params: &[f64]) -> f64 {
    let w0 = st.num_leaves();
    let nse = st.num_sum_edges;
    let mut leaf_ll = vec![0.0f64; w0];
    for i in 0..w0 {
        let v = st.leaf_var[i];
        if marg[v] {
            leaf_ll[i] = 0.0;
        } else {
            let th = params[nse + i].clamp(1e-9, 1.0 - 1e-9);
            leaf_ll[i] = if x[v] == 1 { th.ln() } else { (1.0 - th).ln() };
        }
    }
    let mut vals = vec![leaf_ll.clone()];
    for (li, l) in st.layers.iter().enumerate() {
        let prev_w = if li > 0 { st.layer_widths[li] } else { 0 };
        let get = |c: usize, vals: &Vec<Vec<f64>>| -> f64 {
            if c < prev_w {
                vals[li][c]
            } else {
                vals[0][c - prev_w]
            }
        };
        let out = match l.kind {
            LayerKind::Product => {
                let mut acc = vec![0.0f64; l.width];
                for (&r, &c) in l.rows.iter().zip(&l.cols) {
                    acc[r] += get(c, &vals);
                }
                acc
            }
            LayerKind::Sum => {
                let mut terms: Vec<Vec<f64>> = vec![Vec::new(); l.width];
                for ((&r, &c), &p) in l.rows.iter().zip(&l.cols).zip(&l.param) {
                    let w = params[p as usize].max(1e-30).ln();
                    terms[r].push(w + get(c, &vals));
                }
                terms
                    .into_iter()
                    .map(|t| {
                        let m = t.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                        m + t.iter().map(|v| (v - m).exp()).sum::<f64>().ln()
                    })
                    .collect()
            }
        };
        vals.push(out);
    }
    vals[st.layers.len()][0]
}

/// Mean log-likelihood of a dataset.
pub fn mean_loglik(st: &Structure, data: &[Vec<u8>], params: &[f64]) -> f64 {
    let marg = vec![false; st.num_vars];
    let s: f64 = data.iter().map(|x| logeval(st, x, &marg, params)).sum();
    s / data.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Prng, Rng};

    fn toy() -> Option<Structure> {
        let p = format!("{}/artifacts/toy.structure.json", env!("CARGO_MANIFEST_DIR"));
        std::fs::read_to_string(p).ok().map(|s| Structure::from_json_str(&s).unwrap())
    }

    fn rand_params(st: &Structure, rng: &mut Prng) -> Vec<f64> {
        let mut p = vec![0.0; st.num_params];
        for g in &st.sum_groups {
            let mut tot = 0.0;
            for &i in g {
                p[i] = 0.05 + rng.gen_f64();
                tot += p[i];
            }
            for &i in g {
                p[i] /= tot;
            }
        }
        for i in 0..st.num_leaves() {
            let claim = st.leaf_claim[i];
            p[st.num_sum_edges + i] = match claim {
                1 => 0.95,
                0 => 0.05,
                _ => 0.2 + 0.6 * rng.gen_f64(),
            };
        }
        p
    }

    #[test]
    fn selectivity_and_den_identity() {
        let Some(st) = toy() else { return };
        let mut rng = Prng::seed_from_u64(1);
        let data: Vec<Vec<u8>> = (0..200)
            .map(|_| (0..st.num_vars).map(|_| rng.gen_bool(0.5) as u8).collect())
            .collect();
        let cnt = counts(&st, &data);
        // den (sum node act) equals Σ child act for every sum group
        for g in &st.sum_groups {
            let den = cnt[st.param_den[g[0]]];
            let nums: u64 = g.iter().map(|&p| cnt[st.param_num[p]]).sum();
            assert_eq!(den, nums);
        }
        // root act count = all rows
        assert_eq!(cnt[st.total_nodes - 1], data.len() as u64);
    }

    #[test]
    fn logeval_normalized_over_instance_space() {
        let Some(st) = toy() else { return };
        let mut rng = Prng::seed_from_u64(2);
        let params = rand_params(&st, &mut rng);
        let marg = vec![false; st.num_vars];
        let mut total = 0.0;
        for bits in 0..(1u32 << st.num_vars) {
            let x: Vec<u8> = (0..st.num_vars).map(|v| ((bits >> v) & 1) as u8).collect();
            total += logeval(&st, &x, &marg, &params).exp();
        }
        assert!((total - 1.0).abs() < 1e-9, "Σ S(x) = {total}");
        // all-marginalized = 1
        let z = logeval(&st, &vec![0; st.num_vars], &vec![true; st.num_vars], &params);
        assert!(z.abs() < 1e-9);
    }

    #[test]
    fn counts_additive_over_shards() {
        let Some(st) = toy() else { return };
        let mut rng = Prng::seed_from_u64(3);
        let data: Vec<Vec<u8>> = (0..100)
            .map(|_| (0..st.num_vars).map(|_| rng.gen_bool(0.3) as u8).collect())
            .collect();
        let all = counts(&st, &data);
        let a = counts(&st, &data[..40]);
        let b = counts(&st, &data[40..]);
        let sum: Vec<u64> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        assert_eq!(all, sum);
    }
}
