//! The paper's system coordinators, generic over the transport-agnostic
//! [`MpcSession`](crate::protocols::session::MpcSession) backend: the same
//! code drives the in-process simulation (paper-exact accounting) and real
//! TCP member threads (DESIGN.md §Session API).
//!
//! * [`approx`] — the §3.2 approximate path (additive shares + JRSZ), with
//!   the paper's Example 1 reproduced digit-for-digit in tests; the
//!   session-backed variant runs the same math over any backend.
//! * [`train`]  — the §3.4 exact path: per-party counts → SQ2PQ → one
//!   Newton inversion per sum node → per-edge multiply + truncate.
//! * [`infer`]  — §4 private marginal inference over the learned shares.
//! * [`serve`]  — the standing service: train, then hand the session to
//!   the micro-batching scheduler of `net::serve` (`spn-mpc serve`).

pub mod approx;
pub mod infer;
pub mod serve;
pub mod train;

pub use train::{train, SharedModel, TrainConfig, TrainReport};
